"""Make the L2/L1 `compile` package importable when pytest is invoked
from the repo root (CI runs `python -m pytest python/tests -q`)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
