"""L2 model definitions: shapes, masking, rust-layout export."""

import numpy as np
import jax.numpy as jnp

from compile import datagen, models


def test_alexnet_shapes():
    p = models.init_alexnet(1)
    x = jnp.zeros((2, 3, 32, 32), jnp.float32)
    y = models.alexnet_forward(p, x)
    assert y.shape == (2, 10)
    # 5 convs + 3 fcs, weight + bias each.
    assert len(p) == 16


def test_resnet_shapes_and_plan():
    p = models.init_resnet(2)
    x = jnp.zeros((1, 3, 32, 32), jnp.float32)
    y = models.resnet_forward(p, x)
    assert y.shape == (1, 10)
    plan = models.resnet_conv_plan()
    # stem + 12 block convs + 2 projections = 15 convs (+1 fc head).
    assert len(plan) == 15
    names = [n for n, *_ in plan]
    assert "s2b1d" in names and "s3b1d" in names and "s1b1d" not in names


def test_transformer_shapes_and_pad_mask():
    p = models.init_transformer(3)
    src = jnp.asarray([[5, 6, 7, datagen.EOS] + [datagen.PAD] * 12], jnp.int32)
    enc = models.transformer_encode(p, src)
    assert enc.shape == (1, 16, models.D_MODEL)
    tgt = jnp.asarray([[datagen.BOS, 9, 10] + [datagen.PAD] * 13], jnp.int32)
    logits = models.transformer_decode(p, tgt, enc, src)
    assert logits.shape == (1, 16, models.VOCAB)
    # PAD masking: changing a padded src position must not move logits.
    src2 = src.at[0, 10].set(20)  # still behind EOS/PAD region? position 10 is PAD
    src2 = src2.at[0, 10].set(datagen.PAD)  # keep PAD: identity check
    logits2 = models.transformer_decode(p, tgt, models.transformer_encode(p, src2), src2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), rtol=1e-6)


def test_causal_mask_blocks_future():
    p = models.init_transformer(4)
    src = jnp.asarray([[5, 6, datagen.EOS] + [datagen.PAD] * 13], jnp.int32)
    enc = models.transformer_encode(p, src)
    t1 = jnp.asarray([[datagen.BOS, 7, 8] + [datagen.PAD] * 13], jnp.int32)
    t2 = t1.at[0, 2].set(25)
    l1 = models.transformer_decode(p, t1, enc, src)
    l2 = models.transformer_decode(p, t2, enc, src)
    # Positions 0 and 1 must be identical (pos 2 only feeds later slots).
    np.testing.assert_allclose(np.asarray(l1[0, :2]), np.asarray(l2[0, :2]), rtol=1e-5)


def test_positional_matches_rust_formula():
    pe = models.positional(4, 8)
    assert pe[0, 0] == 0.0 and pe[0, 1] == 1.0
    # pos 2, dim 3 (odd → cos, pair index 1): cos(2 / 10000^(2/8))
    import math

    want = math.cos(2.0 / 10000.0 ** (2.0 / 8.0))
    np.testing.assert_allclose(pe[2, 3], want, rtol=1e-6)


def test_export_reshapes_convs():
    p = models.init_alexnet(5)
    ex = models.export_weights(p, "alexnet_mini")
    assert ex["conv1.w"].shape == (32, 27)
    assert ex["fc1.w"].shape == (256, 1024)
    assert ex["conv1.b"].shape == (32,)
    # Row-major flatten matches rust's [out, c_in*k*k] expectation.
    np.testing.assert_array_equal(
        ex["conv2.w"][0], np.asarray(p["conv2.w"])[0].reshape(-1)
    )


def test_fake_quant_hook_is_applied():
    p = models.init_alexnet(6)
    x = jnp.ones((1, 3, 32, 32), jnp.float32)
    calls = []

    def fq(name, t, which):
        calls.append((name, which))
        return t

    models.alexnet_forward(p, x, fake_quant=fq)
    # 8 layers × (a + w) = 16 hook calls.
    assert len(calls) == 16
    assert ("conv1", "a") in calls and ("fc3", "w") in calls
