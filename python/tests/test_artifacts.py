"""Artifact sanity — runs only when `make artifacts` has produced them."""

import os

import numpy as np
import pytest

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, ".stamp.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_hlo_artifacts_present_and_parsable():
    for name in [
        "alexnet_fp32",
        "resnet_fp32",
        "transformer_enc",
        "transformer_dec",
        "dnateq_fc",
        "pair_hist",
    ]:
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text


@needs_artifacts
def test_weights_load_and_match_manifest():
    import json

    from compile.btio import read_bt

    for model in ["alexnet_mini", "resnet_mini", "transformer_mini"]:
        mdir = os.path.join(ART, "models", model)
        manifest = json.load(open(os.path.join(mdir, "manifest.json")))
        assert manifest["model"] == model
        for name, shape in manifest["tensors"].items():
            arr = read_bt(os.path.join(mdir, f"{name}.bt"))
            assert list(arr.shape) == shape, f"{model}/{name}"
            assert np.isfinite(arr).all(), f"{model}/{name} has non-finite values"


@needs_artifacts
def test_trained_models_beat_chance():
    import json

    a = json.load(open(os.path.join(ART, "models", "alexnet_mini", "manifest.json")))
    r = json.load(open(os.path.join(ART, "models", "resnet_mini", "manifest.json")))
    t = json.load(open(os.path.join(ART, "models", "transformer_mini", "manifest.json")))
    assert a["baseline_top1"] > 0.5, a
    assert r["baseline_top1"] > 0.5, r
    assert t["baseline_token_acc"] > 0.5, t


@needs_artifacts
def test_datasets_dumped():
    from compile.btio import read_bt

    imgs = read_bt(os.path.join(ART, "data", "eval_images.bt"))
    labels = read_bt(os.path.join(ART, "data", "eval_labels.bt"))
    assert imgs.shape[0] == labels.shape[0] == 512
    src = read_bt(os.path.join(ART, "data", "eval_src.bt"))
    tgt = read_bt(os.path.join(ART, "data", "eval_tgt.bt"))
    assert src.shape == tgt.shape == (256, 16)


@needs_artifacts
def test_quantized_fc_hlo_contains_quantizer_math():
    """The dnateq_fc artifact must actually contain the L1 kernel lowered
    inline (log/exponential ops), not a plain matmul."""
    text = open(os.path.join(ART, "dnateq_fc.hlo.txt")).read()
    assert "log(" in text or "log." in text or "exponential" in text, "no quantizer math found"
    assert "dot(" in text or "dot." in text or "dot " in text
