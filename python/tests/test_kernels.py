"""Pallas kernels vs pure-jnp oracles — the CORE L1 correctness signal.

Hypothesis sweeps shapes, bitwidths and quantizer parameters; assertions
are `assert_allclose` (exact for integer outputs).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.exp_dot import (
    exp_dot_pallas,
    pair_histogram_pallas,
    single_histogram_pallas,
)
from compile.kernels.exp_quant import exp_encode_pallas, exp_roundtrip_pallas

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def signed_expo(n, seed, scale=0.5, zero_every=7):
    rng = np.random.default_rng(seed)
    x = np.sign(rng.standard_normal(n)) * rng.exponential(scale, n)
    if zero_every:
        x[::zero_every] = 0.0
    return jnp.asarray(x, dtype=jnp.float32)


@given(
    n=st.integers(1, 5000),
    n_bits=st.integers(3, 7),
    base=st.floats(1.05, 1.9),
    alpha=st.floats(0.01, 2.0),
    beta=st.floats(0.0, 0.05),
    seed=st.integers(0, 2**31),
)
def test_roundtrip_matches_ref(n, n_bits, base, alpha, beta, seed):
    x = signed_expo(n, seed)
    want = ref.exp_roundtrip_ref(x, base, alpha, beta, n_bits)
    got = exp_roundtrip_pallas(x, base, alpha, beta, n_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@given(
    n=st.integers(1, 5000),
    n_bits=st.integers(3, 7),
    base=st.floats(1.05, 1.9),
    seed=st.integers(0, 2**31),
)
def test_encode_matches_ref(n, n_bits, base, seed):
    x = signed_expo(n, seed)
    want_c, want_s = ref.exp_encode_ref(x, base, 0.3, 0.001, n_bits)
    got_c, got_s = exp_encode_pallas(x, base, 0.3, 0.001, n_bits)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def _codes(n, n_bits, seed):
    x = signed_expo(n, seed)
    return ref.exp_encode_ref(x, 1.3, 0.4, 0.002, n_bits)


@given(n=st.integers(1, 40000), n_bits=st.integers(3, 7), seed=st.integers(0, 2**31))
def test_pair_histogram_matches_ref(n, n_bits, seed):
    ac, asn = _codes(n, n_bits, seed)
    wc, wsn = _codes(n, n_bits, seed + 1)
    want = ref.pair_histogram_ref(ac, asn, wc, wsn, n_bits)
    got = pair_histogram_pallas(ac, asn, wc, wsn, n_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(n=st.integers(1, 20000), n_bits=st.integers(3, 7), seed=st.integers(0, 2**31))
def test_single_histogram_matches_ref(n, n_bits, seed):
    ac, asn = _codes(n, n_bits, seed)
    wc, wsn = _codes(n, n_bits, seed + 1)
    want = ref.single_histogram_ref(wc, asn * wsn, ac, n_bits)
    got = single_histogram_pallas(wc, wsn, ac, asn, n_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(n=st.integers(8, 8000), n_bits=st.integers(3, 6), seed=st.integers(0, 2**31))
def test_exp_dot_matches_ref(n, n_bits, seed):
    ac, asn = _codes(n, n_bits, seed)
    wc, wsn = _codes(n, n_bits, seed + 1)
    args = (1.3, 0.4, 0.002, 0.1, 0.001, n_bits)
    want = float(ref.exp_dot_ref(ac, asn, wc, wsn, *args))
    got = float(exp_dot_pallas(ac, asn, wc, wsn, *args))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_exp_dot_equals_dequantized_dot():
    """Eq. 8 sanity: counting reconstruction == dot of dequantized values."""
    n_bits = 5
    x = signed_expo(3000, 42)
    w = signed_expo(3000, 43, scale=0.15)
    base, aa, ba, aw, bw = 1.22, 0.4, 0.003, 0.05, 0.0005
    ac, asn = ref.exp_encode_ref(x, base, aa, ba, n_bits)
    wc, wsn = ref.exp_encode_ref(w, base, aw, bw, n_bits)
    got = float(ref.exp_dot_ref(ac, asn, wc, wsn, base, aa, ba, aw, bw, n_bits))
    xq = np.asarray(ref.exp_roundtrip_ref(x, base, aa, ba, n_bits), dtype=np.float64)
    wq = np.asarray(ref.exp_roundtrip_ref(w, base, aw, bw, n_bits), dtype=np.float64)
    np.testing.assert_allclose(got, float(xq @ wq), rtol=1e-3)


def test_zero_preservation():
    x = jnp.zeros(100, dtype=jnp.float32)
    out = exp_roundtrip_pallas(x, 1.3, 1.0, 0.01, 4)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(100, np.float32))
    codes, signs = exp_encode_pallas(x, 1.3, 1.0, 0.01, 4)
    assert (np.asarray(codes) == -8).all()
    assert (np.asarray(signs) == 1).all()


@pytest.mark.parametrize("n_bits", [3, 4, 5, 6, 7])
def test_codes_within_clip_range(n_bits):
    x = signed_expo(4096, 7, scale=2.0)
    codes, _ = exp_encode_pallas(x, 1.4, 0.2, 0.001, n_bits)
    c = np.asarray(codes)
    rm = (1 << (n_bits - 1)) - 1
    nz = c[c != -(1 << (n_bits - 1))]
    assert nz.min() >= -rm and nz.max() <= rm


def test_rmae_decreases_with_bitwidth():
    """More exponent bits → lower quantization error (Eq. 6 monotonicity)."""
    x = signed_expo(8192, 11)
    prev = np.inf
    for n_bits in range(3, 8):
        rm = (1 << (n_bits - 1)) - 1
        base = float(np.abs(np.asarray(x)).max()) ** (1.0 / rm)
        base = max(base, 1.0001)
        alpha = float(np.abs(np.asarray(x)).max()) / base**rm
        q = np.asarray(exp_roundtrip_pallas(x, base, alpha, 0.0, n_bits))
        xa = np.abs(np.asarray(x))
        rmae = np.abs(np.abs(q) - xa).sum() / xa.sum()
        assert rmae < prev * 1.05, f"n={n_bits}: {rmae} vs {prev}"
        prev = rmae
