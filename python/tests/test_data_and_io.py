"""Dataset spec (shared with rust) + `.bt` interchange."""

import os

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import datagen
from compile.btio import read_bt, write_bt

settings.register_profile("ci2", max_examples=25, deadline=None)
settings.load_profile("ci2")


def test_cipher_is_bijective():
    toks = np.arange(3, datagen.VOCAB)
    out = datagen.cipher(toks)
    assert sorted(out.tolist()) == toks.tolist()


def test_translate_spec():
    payload = np.array([3, 10, 20])
    t = datagen.translate(payload)
    assert t.tolist() == [int(datagen.cipher(20)), int(datagen.cipher(10)), int(datagen.cipher(3))]


def test_gen_seqs_structure():
    src, tgt = datagen.gen_seqs(50, 1)
    assert src.shape == (50, datagen.MAX_LEN)
    for i in range(50):
        s = src[i][src[i] != datagen.PAD]
        t = tgt[i][tgt[i] != datagen.PAD]
        assert s[-1] == datagen.EOS and t[0] == datagen.BOS and t[-1] == datagen.EOS
        payload = s[:-1]
        np.testing.assert_array_equal(t[1:-1], datagen.translate(payload))


def test_gen_images_stats():
    imgs, labels = datagen.gen_images(64, 2)
    assert imgs.shape == (64, 3, 32, 32)
    assert imgs.dtype == np.float32
    assert labels.min() >= 0 and labels.max() <= 9
    # Signal + bounded noise stays in a sane range.
    assert np.abs(imgs).max() < 2.0


@given(
    shape=st.lists(st.integers(1, 6), min_size=1, max_size=4),
    seed=st.integers(0, 2**31),
)
def test_bt_roundtrip_f32(shape, seed):
    rng = np.random.default_rng(seed)
    arr = rng.standard_normal(shape).astype(np.float32)
    path = f"/tmp/dnateq-pytest-{os.getpid()}.bt"
    write_bt(path, arr)
    back = read_bt(path)
    np.testing.assert_array_equal(back, arr)
    os.remove(path)


def test_bt_roundtrip_i32():
    arr = np.array([[1, -2], [3, 4]], dtype=np.int32)
    path = f"/tmp/dnateq-pytest-i32-{os.getpid()}.bt"
    write_bt(path, arr)
    back = read_bt(path)
    assert back.dtype == np.int32
    np.testing.assert_array_equal(back, arr)
    os.remove(path)


def test_bt_rejects_bad_magic():
    path = f"/tmp/dnateq-pytest-bad-{os.getpid()}.bt"
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 16)
    try:
        read_bt(path)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass
    finally:
        os.remove(path)
