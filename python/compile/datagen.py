"""Synthetic dataset generators (DESIGN.md substitutions for ImageNet/WMT).

The *task specs* are shared verbatim with the rust side
(``rust/src/dataset/mod.rs``): images are class-dependent frequency
patterns plus noise; translation is reverse + substitution cipher over a
29-symbol payload alphabet. RNG streams do not need to match across
languages — rust consumes the dumped ``.bt`` splits.
"""

from __future__ import annotations

import numpy as np

# Token conventions (shared with rust/src/nn/transformer.rs).
PAD, BOS, EOS = 0, 1, 2
VOCAB = 32
MAX_LEN = 16  # padded sequence length in the dumped matrices


def cipher(tok: np.ndarray | int):
    """Bijection over the payload alphabet [3, VOCAB)."""
    payload = VOCAB - 3  # 29, coprime with 5
    return 3 + ((np.asarray(tok) - 3) * 5 + 7) % payload


def translate(src_payload: np.ndarray) -> np.ndarray:
    """Reference translation: reverse then cipher."""
    return cipher(src_payload[::-1])


def gen_images(
    n: int, seed: int, margin: float = 0.12, noise: float = 0.55
) -> tuple[np.ndarray, np.ndarray]:
    """Images ``[n, 3, 32, 32]`` f32 + labels ``[n]`` i32.

    Amplitude-discrimination task: each image superposes the *label*
    class pattern (oriented sinusoid, frequency ``(1 + c%5, 1 + 2(c//5))``)
    at amplitude ``0.5 + margin/2`` with a random *distractor* class
    pattern at ``0.5 − margin/2``, plus uniform noise. Telling dominant
    from distractor requires precise filter weights — low-bit naive
    quantization visibly hurts (the regime the paper's CNNs live in,
    landing them at ~5.7 average bits), while a trained CNN still reaches
    ≥95% in FP32.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    xs = np.arange(32, dtype=np.float32)
    gx, gy = np.meshgrid(xs, xs, indexing="xy")

    def pat(c: int, phase: float) -> np.ndarray:
        fx = 1.0 + (c % 5)
        fy = 1.0 + 2.0 * (c // 5)
        return np.sin(gx * fx / 32.0 * 2 * np.pi + gy * fy / 32.0 * 2 * np.pi + phase)

    images = np.empty((n, 3, 32, 32), dtype=np.float32)
    for i, c in enumerate(labels):
        d = (c + 1 + rng.integers(0, 9)) % 10  # distractor class != c
        base = (0.5 + margin / 2) * pat(c, rng.uniform(0, 2 * np.pi)) + (
            0.5 - margin / 2
        ) * pat(d, rng.uniform(0, 2 * np.pi))
        for ch in range(3):
            images[i, ch] = base * (1.0 - 0.2 * ch) + rng.uniform(
                -noise, noise, size=(32, 32)
            ).astype(np.float32)
    return images, labels


def gen_seqs(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """PAD-filled ``[n, MAX_LEN]`` i32 matrices (src, tgt).

    src = payload ++ EOS; tgt = BOS ++ translate(payload) ++ EOS.
    Payload length 4..=12 (fits MAX_LEN=16 with the frame tokens).
    """
    rng = np.random.default_rng(seed)
    src = np.full((n, MAX_LEN), PAD, dtype=np.int32)
    tgt = np.full((n, MAX_LEN), PAD, dtype=np.int32)
    for i in range(n):
        ln = int(rng.integers(4, 13))
        payload = rng.integers(3, VOCAB, size=ln).astype(np.int32)
        src[i, :ln] = payload
        src[i, ln] = EOS
        tr = translate(payload)
        tgt[i, 0] = BOS
        tgt[i, 1 : ln + 1] = tr
        tgt[i, ln + 1] = EOS
    return src, tgt
