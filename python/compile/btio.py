"""`.bt` binary tensor interchange with the rust side.

Layout (little-endian), mirrored in ``rust/src/tensor/io.rs``::

    magic   : 4 bytes  b"BT01"
    dtype   : u32      0 = f32, 1 = i8, 2 = i32
    ndim    : u32
    dims    : ndim x u64
    payload : prod(dims) x sizeof(dtype)
"""

from __future__ import annotations

import os
import struct

import numpy as np

MAGIC = b"BT01"
_DTYPES = {0: np.float32, 1: np.int8, 2: np.int32}
_TAGS = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}


def write_bt(path: str, arr: np.ndarray) -> None:
    """Write an array as `.bt`, creating parent directories."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _TAGS:
        # Normalize common trainer dtypes.
        if np.issubdtype(arr.dtype, np.floating):
            arr = arr.astype(np.float32)
        elif np.issubdtype(arr.dtype, np.integer):
            arr = arr.astype(np.int32)
        else:
            raise TypeError(f"unsupported dtype {arr.dtype}")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", _TAGS[arr.dtype], arr.ndim))
        for d in arr.shape:
            f.write(struct.pack("<Q", d))
        f.write(arr.tobytes())


def read_bt(path: str) -> np.ndarray:
    """Read a `.bt` file back into a numpy array."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r} in {path}")
        tag, ndim = struct.unpack("<II", f.read(8))
        dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
        dtype = _DTYPES[tag]
        n = int(np.prod(dims)) if dims else 1
        data = np.frombuffer(f.read(n * np.dtype(dtype).itemsize), dtype=dtype)
        return data.reshape(dims)
