"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package is checked against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes, bitwidths and
parameters). The math mirrors Eqs. 2–5 (quantizer) and Eq. 8 (counting
dot product) and is also the spec the rust engine implements.
"""

from __future__ import annotations

import jax.numpy as jnp


def r_max(n_bits: int) -> int:
    """R_max = 2^{n-1} - 1 (Eq. 2)."""
    return (1 << (n_bits - 1)) - 1


def exp_roundtrip_ref(x, base, alpha, beta, n_bits: int):
    """Fake-quantization: quantize-dequantize with the exponential scheme.

    `x̄ = sign(x)·(α·b^i + β)` with `i = clip(round(log_b((|x|−β)/α)))`;
    exact zeros map to zero (the reserved code, §III-B); magnitudes below
    the smallest interval clamp to `R_min`.
    """
    rm = r_max(n_bits)
    mag = jnp.abs(x)
    arg = (mag - beta) / alpha
    safe = jnp.maximum(arg, 1e-30)
    i = jnp.round(jnp.log(safe) / jnp.log(base))
    i = jnp.where(arg <= 0.0, -rm, i)
    i = jnp.clip(i, -rm, rm)
    q = alpha * jnp.power(base, i) + beta
    return jnp.where(x == 0.0, 0.0, jnp.sign(x) * q).astype(x.dtype)


def exp_encode_ref(x, base, alpha, beta, n_bits: int):
    """Exponent codes + signs. Zero uses code `-2^{n-1}` (= R_min − 1)."""
    rm = r_max(n_bits)
    mag = jnp.abs(x)
    arg = (mag - beta) / alpha
    safe = jnp.maximum(arg, 1e-30)
    i = jnp.round(jnp.log(safe) / jnp.log(base))
    i = jnp.where(arg <= 0.0, -rm, i)
    i = jnp.clip(i, -rm, rm)
    zero_code = -(1 << (n_bits - 1))
    codes = jnp.where(x == 0.0, zero_code, i).astype(jnp.int32)
    signs = jnp.where(x < 0.0, -1, 1).astype(jnp.int32)
    return codes, signs


def pair_histogram_ref(a_codes, a_signs, w_codes, w_signs, n_bits: int):
    """Counting stage of Eq. 8, term 1: signed histogram of exponent sums.

    ``hist[k] = Σ_i s_i · 1[a_i + w_i = k − 2·R_max]`` over the pairs where
    neither side is the zero code. Table length `4·R_max + 1 ≤ 2^{n+1}`.
    """
    rm = r_max(n_bits)
    zero_code = -(1 << (n_bits - 1))
    valid = (a_codes != zero_code) & (w_codes != zero_code)
    s = (a_signs * w_signs) * valid.astype(jnp.int32)
    idx = jnp.clip(a_codes + w_codes + 2 * rm, 0, 4 * rm)
    hist = jnp.zeros(4 * rm + 1, dtype=jnp.int32)
    return hist.at[idx].add(s)


def single_histogram_ref(codes, pair_signs, other_codes, n_bits: int):
    """Counting stage, terms 2/3: signed histogram of one side's exponents
    (masked where either side is zero)."""
    rm = r_max(n_bits)
    zero_code = -(1 << (n_bits - 1))
    valid = (codes != zero_code) & (other_codes != zero_code)
    s = pair_signs * valid.astype(jnp.int32)
    idx = jnp.clip(codes + rm, 0, 2 * rm)
    hist = jnp.zeros(2 * rm + 1, dtype=jnp.int32)
    return hist.at[idx].add(s)


def exp_dot_ref(
    a_codes, a_signs, w_codes, w_signs, base, alpha_a, beta_a, alpha_w, beta_w, n_bits: int
):
    """Full exponential dot product (Eq. 8): histograms → BLUT → 4 terms."""
    rm = r_max(n_bits)
    pair = pair_histogram_ref(a_codes, a_signs, w_codes, w_signs, n_bits)
    s = a_signs * w_signs
    wh = single_histogram_ref(w_codes, s, a_codes, n_bits)
    ah = single_histogram_ref(a_codes, s, w_codes, n_bits)
    sign_count = jnp.sum(pair)
    blut_pair = jnp.power(base, jnp.arange(-2 * rm, 2 * rm + 1, dtype=jnp.float32))
    blut_single = jnp.power(base, jnp.arange(-rm, rm + 1, dtype=jnp.float32))
    t1 = jnp.sum(pair * blut_pair)
    t2 = jnp.sum(wh * blut_single)
    t3 = jnp.sum(ah * blut_single)
    return (
        alpha_a * alpha_w * t1
        + alpha_w * beta_a * t2
        + alpha_a * beta_w * t3
        + beta_a * beta_w * sign_count
    )
