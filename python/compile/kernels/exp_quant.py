"""L1 Pallas kernel: LogExpQuant (Eqs. 2–3) as a tiled elementwise pass.

TPU mapping (DESIGN.md §Hardware-Adaptation): the quantizer is a pure
VPU elementwise kernel — `BlockSpec` tiles of 256×128 f32 (128 KiB)
stream HBM→VMEM while the log/round/clip pipeline runs at vector rate.
`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime can load (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-friendly tile: 256×128 f32 = 128 KiB per operand buffer.
TILE_ROWS = 256
TILE_COLS = 128


def _roundtrip_kernel(x_ref, o_ref, *, base, alpha, beta, rm):
    x = x_ref[...]
    mag = jnp.abs(x)
    arg = (mag - beta) / alpha
    safe = jnp.maximum(arg, 1e-30)
    i = jnp.round(jnp.log(safe) * (1.0 / jnp.log(base)))
    i = jnp.where(arg <= 0.0, -float(rm), i)
    i = jnp.clip(i, -float(rm), float(rm))
    q = alpha * jnp.exp(i * jnp.log(base)) + beta
    o_ref[...] = jnp.where(x == 0.0, 0.0, jnp.sign(x) * q).astype(x.dtype)


def exp_roundtrip_pallas(x, base: float, alpha: float, beta: float, n_bits: int):
    """Fake-quantize an arbitrary-shape f32 tensor with the exponential
    scheme. Tiles the flattened tensor; remainder handled by padding with
    zeros (which quantize to exact zeros)."""
    rm = (1 << (n_bits - 1)) - 1
    orig_shape = x.shape
    flat = x.reshape(-1)
    tile = TILE_ROWS * TILE_COLS
    n = flat.shape[0]
    pad = (-n) % tile
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, dtype=flat.dtype)])
    grid = flat.shape[0] // tile
    out = pl.pallas_call(
        functools.partial(
            _roundtrip_kernel, base=float(base), alpha=float(alpha), beta=float(beta), rm=rm
        ),
        out_shape=jax.ShapeDtypeStruct((grid * TILE_ROWS, TILE_COLS), flat.dtype),
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i: (i, 0)),
        interpret=True,
    )(flat.reshape(grid * TILE_ROWS, TILE_COLS))
    return out.reshape(-1)[:n].reshape(orig_shape)


def _encode_kernel(x_ref, code_ref, sign_ref, *, base, alpha, beta, rm, zero_code):
    x = x_ref[...]
    mag = jnp.abs(x)
    arg = (mag - beta) / alpha
    safe = jnp.maximum(arg, 1e-30)
    i = jnp.round(jnp.log(safe) * (1.0 / jnp.log(base)))
    i = jnp.where(arg <= 0.0, -float(rm), i)
    i = jnp.clip(i, -float(rm), float(rm))
    code_ref[...] = jnp.where(x == 0.0, zero_code, i.astype(jnp.int32)).astype(jnp.int32)
    sign_ref[...] = jnp.where(x < 0.0, -1, 1).astype(jnp.int32)


def exp_encode_pallas(x, base: float, alpha: float, beta: float, n_bits: int):
    """Quantize to (codes, signs) — the runtime Quantizer stage (§V-B)."""
    rm = (1 << (n_bits - 1)) - 1
    zero_code = -(1 << (n_bits - 1))
    orig_shape = x.shape
    flat = x.reshape(-1)
    tile = TILE_ROWS * TILE_COLS
    n = flat.shape[0]
    pad = (-n) % tile
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, dtype=flat.dtype)])
    grid = flat.shape[0] // tile
    codes, signs = pl.pallas_call(
        functools.partial(
            _encode_kernel,
            base=float(base),
            alpha=float(alpha),
            beta=float(beta),
            rm=rm,
            zero_code=zero_code,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((grid * TILE_ROWS, TILE_COLS), jnp.int32),
            jax.ShapeDtypeStruct((grid * TILE_ROWS, TILE_COLS), jnp.int32),
        ),
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i: (i, 0)),
        ),
        interpret=True,
    )(flat.reshape(grid * TILE_ROWS, TILE_COLS))
    codes = codes.reshape(-1)[:n].reshape(orig_shape)
    signs = signs.reshape(-1)[:n].reshape(orig_shape)
    return codes, signs
