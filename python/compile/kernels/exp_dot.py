"""L1 Pallas kernel: the counting stage of the exponential dot product.

TPU mapping (DESIGN.md §Hardware-Adaptation): a custom increment datapath
does not exist on TPU, so the signed exponent histogram is formulated as
a **one-hot contraction on the MXU** — each reduction block builds a
`[K_table, block]` one-hot of the pair indices and contracts it with the
signed-validity vector, accumulating the table in VMEM across the grid
(the table is ≤ 253 f32 ≈ 1 KiB, trivially resident; the block operands
are 2×128·128 i32 = 128 KiB).

`interpret=True` for CPU execution (see exp_quant.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Reduction block per grid step.
BLOCK = 128 * 128


def _pair_hist_kernel(a_code_ref, a_sign_ref, w_code_ref, w_sign_ref, hist_ref, *, rm, zero_code, k_table):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    ac = a_code_ref[...].reshape(-1)
    asn = a_sign_ref[...].reshape(-1)
    wc = w_code_ref[...].reshape(-1)
    wsn = w_sign_ref[...].reshape(-1)
    valid = (ac != zero_code) & (wc != zero_code)
    s = (asn * wsn * valid.astype(jnp.int32)).astype(jnp.float32)
    idx = jnp.clip(ac + wc + 2 * rm, 0, k_table - 1)
    # One-hot contraction — the MXU-friendly histogram (f32 accumulate).
    onehot = (idx[None, :] == jnp.arange(k_table, dtype=jnp.int32)[:, None]).astype(jnp.float32)
    hist_ref[...] += onehot @ s


def pair_histogram_pallas(a_codes, a_signs, w_codes, w_signs, n_bits: int):
    """Signed histogram of exponent sums (term 1 of Eq. 8).

    Inputs are flat i32 vectors of equal length; zero-code pairs are
    skipped. Returns an i32 table of length `4·R_max + 1`.
    """
    rm = (1 << (n_bits - 1)) - 1
    zero_code = -(1 << (n_bits - 1))
    k_table = 4 * rm + 1
    n = a_codes.shape[0]
    pad = (-n) % BLOCK
    z = lambda v, fill: jnp.concatenate([v, jnp.full(pad, fill, dtype=v.dtype)]) if pad else v
    # Padding uses the zero code → masked out of every term.
    a_codes = z(a_codes.astype(jnp.int32), zero_code)
    w_codes = z(w_codes.astype(jnp.int32), zero_code)
    a_signs = z(a_signs.astype(jnp.int32), 1)
    w_signs = z(w_signs.astype(jnp.int32), 1)
    grid = a_codes.shape[0] // BLOCK
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    hist = pl.pallas_call(
        functools.partial(_pair_hist_kernel, rm=rm, zero_code=zero_code, k_table=k_table),
        out_shape=jax.ShapeDtypeStruct((k_table,), jnp.float32),
        grid=(grid,),
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((k_table,), lambda i: (0,)),
        interpret=True,
    )(a_codes, a_signs, w_codes, w_signs)
    return hist.astype(jnp.int32)


def _single_hist_kernel(code_ref, sign_ref, other_ref, osign_ref, hist_ref, *, rm, zero_code, k_table):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    c = code_ref[...].reshape(-1)
    s1 = sign_ref[...].reshape(-1)
    o = other_ref[...].reshape(-1)
    s2 = osign_ref[...].reshape(-1)
    valid = (c != zero_code) & (o != zero_code)
    s = (s1 * s2 * valid.astype(jnp.int32)).astype(jnp.float32)
    idx = jnp.clip(c + rm, 0, k_table - 1)
    onehot = (idx[None, :] == jnp.arange(k_table, dtype=jnp.int32)[:, None]).astype(jnp.float32)
    hist_ref[...] += onehot @ s


def single_histogram_pallas(codes, signs, other_codes, other_signs, n_bits: int):
    """Signed histogram of one side's exponents (terms 2/3 of Eq. 8)."""
    rm = (1 << (n_bits - 1)) - 1
    zero_code = -(1 << (n_bits - 1))
    k_table = 2 * rm + 1
    n = codes.shape[0]
    pad = (-n) % BLOCK
    z = lambda v, fill: jnp.concatenate([v, jnp.full(pad, fill, dtype=v.dtype)]) if pad else v
    codes = z(codes.astype(jnp.int32), zero_code)
    other_codes = z(other_codes.astype(jnp.int32), zero_code)
    signs = z(signs.astype(jnp.int32), 1)
    other_signs = z(other_signs.astype(jnp.int32), 1)
    grid = codes.shape[0] // BLOCK
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    hist = pl.pallas_call(
        functools.partial(_single_hist_kernel, rm=rm, zero_code=zero_code, k_table=k_table),
        out_shape=jax.ShapeDtypeStruct((k_table,), jnp.float32),
        grid=(grid,),
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((k_table,), lambda i: (0,)),
        interpret=True,
    )(codes, signs, other_codes, other_signs)
    return hist.astype(jnp.int32)


def exp_dot_pallas(
    a_codes, a_signs, w_codes, w_signs, base, alpha_a, beta_a, alpha_w, beta_w, n_bits: int
):
    """Full exponential dot product: Pallas counting stage + jnp
    post-processing (mirroring the hardware's counting/Dequantizer split,
    §V-C/D)."""
    rm = (1 << (n_bits - 1)) - 1
    pair = pair_histogram_pallas(a_codes, a_signs, w_codes, w_signs, n_bits)
    wh = single_histogram_pallas(w_codes, w_signs, a_codes, a_signs, n_bits)
    ah = single_histogram_pallas(a_codes, a_signs, w_codes, w_signs, n_bits)
    sign_count = jnp.sum(pair)
    blut_pair = jnp.power(base, jnp.arange(-2 * rm, 2 * rm + 1, dtype=jnp.float32))
    blut_single = jnp.power(base, jnp.arange(-rm, rm + 1, dtype=jnp.float32))
    t1 = jnp.sum(pair * blut_pair)
    t2 = jnp.sum(wh * blut_single)
    t3 = jnp.sum(ah * blut_single)
    return (
        alpha_a * alpha_w * t1
        + alpha_w * beta_a * t2
        + alpha_a * beta_w * t3
        + beta_a * beta_w * sign_count
    )
