# L1: Pallas kernels for DNA-TEQ's compute hot spots (exponential
# quantizer + counting dot-product), validated against ref.py oracles.
