"""AOT build entrypoint (`make artifacts` → `python -m compile.aot`).

Runs ONCE at build time; python never touches the request path. Steps:

1. generate the synthetic datasets (train/calib/eval splits) → `data/*.bt`
2. train the three mini models → `models/<name>/*.bt` + `manifest.json`
3. lower HLO **text** artifacts for the rust PJRT runtime:
     - `<model>_fp32.hlo.txt` — FP32 forward, weights baked in
     - `transformer_enc/dec.hlo.txt` — fixed-shape encoder/decoder
     - `dnateq_fc.hlo.txt` — an FC layer whose weights & input run through
       the L1 Pallas exponential quantizer (proves L1→L2→L3 composition)
     - `pair_hist.hlo.txt` — the L1 counting-stage kernel standalone

HLO text (not serialized protos) is the interchange format — jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

With ``--quantized <config.json>`` (a rust-calibrated QuantConfig) it
additionally lowers `alexnet_dnateq.hlo.txt`, the fully DNA-TEQ-quantized
classifier.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, models, train
from .btio import write_bt
from .kernels.exp_dot import pair_histogram_pallas
from .kernels.exp_quant import exp_roundtrip_pallas

SEED = 20230713
STAMP_VERSION = 8  # bump to force a rebuild


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked-in trained weights MUST
    # survive the text round-trip (default printing elides them as
    # `constant({...})`, which parses back as zeros on the rust side).
    return comp.as_hlo_text(True)


def dump_hlo(path: str, fn, *arg_specs):
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def build_datasets(data_dir: str, log=print):
    log("[1/3] datasets")
    splits = {
        "train": datagen.gen_images(2048, SEED),
        "calib": datagen.gen_images(48, SEED + 1),
        "eval": datagen.gen_images(512, SEED + 2),
    }
    for split, (imgs, labels) in splits.items():
        write_bt(os.path.join(data_dir, f"{split}_images.bt"), imgs)
        write_bt(os.path.join(data_dir, f"{split}_labels.bt"), labels)
    seq_splits = {
        "train": datagen.gen_seqs(8192, SEED + 3),
        "calib": datagen.gen_seqs(48, SEED + 4),
        "eval": datagen.gen_seqs(256, SEED + 5),
    }
    for split, (src, tgt) in seq_splits.items():
        write_bt(os.path.join(data_dir, f"{split}_src.bt"), src)
        write_bt(os.path.join(data_dir, f"{split}_tgt.bt"), tgt)
    return splits, seq_splits


def train_models(art: str, splits, seq_splits, steps_scale: float, log=print):
    log("[2/3] training mini models (build-time only)")
    imgs, labels = splits["train"]
    eimgs, elabels = splits["eval"]
    manifest = {}

    log(" alexnet_mini")
    p = models.init_alexnet(SEED + 10)
    p = train.train_classifier(
        models.alexnet_forward, p, imgs, labels,
        steps=int(320 * steps_scale), batch=24, lr=1.5e-3, seed=SEED + 11, log=log,
    )
    acc = train.eval_classifier(models.alexnet_forward, p, eimgs, elabels)
    log(f"  alexnet_mini eval top-1 = {acc:.4f}")
    save_model(art, "alexnet_mini", p, {"baseline_top1": acc})
    manifest["alexnet_mini"] = (p, acc)

    log(" resnet_mini")
    p = models.init_resnet(SEED + 20)
    p = train.train_classifier(
        models.resnet_forward, p, imgs, labels,
        steps=int(300 * steps_scale), batch=24, lr=1e-3, seed=SEED + 21, log=log,
    )
    acc = train.eval_classifier(models.resnet_forward, p, eimgs, elabels)
    log(f"  resnet_mini eval top-1 = {acc:.4f}")
    save_model(art, "resnet_mini", p, {"baseline_top1": acc})
    manifest["resnet_mini"] = (p, acc)

    log(" transformer_mini")
    src, tgt = seq_splits["train"]
    esrc, etgt = seq_splits["eval"]
    p = models.init_transformer(SEED + 30)
    p = train.train_transformer(
        p, src, tgt, steps=int(1400 * steps_scale), batch=48, lr=2e-3, seed=SEED + 31, log=log
    )
    acc = train.eval_transformer(p, esrc, etgt)
    log(f"  transformer_mini eval token-acc = {acc:.4f}")
    save_model(art, "transformer_mini", p, {"baseline_token_acc": acc})
    manifest["transformer_mini"] = (p, acc)
    return manifest


def save_model(art: str, name: str, params: dict, metrics: dict):
    mdir = os.path.join(art, "models", name)
    exported = models.export_weights(params, name)
    for k, v in exported.items():
        write_bt(os.path.join(mdir, f"{k}.bt"), v)
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(
            {"model": name, "tensors": {k: list(v.shape) for k, v in exported.items()}, **metrics},
            f,
            indent=1,
        )


def lower_hlo(art: str, trained, log=print):
    log("[3/3] lowering HLO artifacts")
    f32 = jnp.float32

    alex_p, _ = trained["alexnet_mini"]
    dump_hlo(
        os.path.join(art, "alexnet_fp32.hlo.txt"),
        lambda x: (models.alexnet_forward(alex_p, x),),
        jax.ShapeDtypeStruct((1, 3, 32, 32), f32),
    )

    res_p, _ = trained["resnet_mini"]
    dump_hlo(
        os.path.join(art, "resnet_fp32.hlo.txt"),
        lambda x: (models.resnet_forward(res_p, x),),
        jax.ShapeDtypeStruct((1, 3, 32, 32), f32),
    )

    tr_p, _ = trained["transformer_mini"]
    L = datagen.MAX_LEN
    dump_hlo(
        os.path.join(art, "transformer_enc.hlo.txt"),
        lambda src: (models.transformer_encode(tr_p, src),),
        jax.ShapeDtypeStruct((1, L), jnp.int32),
    )
    dump_hlo(
        os.path.join(art, "transformer_dec.hlo.txt"),
        lambda tgt, enc, src: (models.transformer_decode(tr_p, tgt, enc, src),),
        jax.ShapeDtypeStruct((1, L), jnp.int32),
        jax.ShapeDtypeStruct((1, L, models.D_MODEL), f32),
        jax.ShapeDtypeStruct((1, L), jnp.int32),
    )

    # L1→L2→L3 composition proof: FC whose weights AND input pass through
    # the Pallas exponential quantizer, lowered into one HLO the rust
    # runtime executes and cross-checks against its own engine.
    w_demo = np.asarray(alex_p["fc2.w"])  # [128, 256]
    qparams = dict(base=1.22, alpha=float(np.abs(w_demo).max() / 1.22**7), beta=0.0, n_bits=4)

    def dnateq_fc(x):
        wq = exp_roundtrip_pallas(jnp.asarray(w_demo), **qparams)
        xq = exp_roundtrip_pallas(x, 1.22, 0.05, 0.0, 4)
        return (xq @ wq.T,)

    dump_hlo(
        os.path.join(art, "dnateq_fc.hlo.txt"),
        dnateq_fc,
        jax.ShapeDtypeStruct((1, 256), f32),
    )

    # Standalone counting-stage kernel (term-1 histogram, n=4 → 29 bins).
    def pair_hist(ac, asn, wc, wsn):
        return (pair_histogram_pallas(ac, asn, wc, wsn, 4),)

    i32 = jnp.int32
    dump_hlo(
        os.path.join(art, "pair_hist.hlo.txt"),
        pair_hist,
        jax.ShapeDtypeStruct((4096,), i32),
        jax.ShapeDtypeStruct((4096,), i32),
        jax.ShapeDtypeStruct((4096,), i32),
        jax.ShapeDtypeStruct((4096,), i32),
    )


def load_params_from_bt(art: str, model: str) -> dict:
    """Rebuild a jax param dict from the dumped .bt weights (conv tensors
    are re-folded to OIHW). Enables re-lowering HLO without retraining."""
    from .btio import read_bt

    mdir = os.path.join(art, "models", model)
    params = {}
    for fn in sorted(os.listdir(mdir)):
        if not fn.endswith(".bt"):
            continue
        key = fn[: -len(".bt")]
        arr = read_bt(os.path.join(mdir, fn))
        if model == "alexnet_mini" and key.endswith(".w") and key.startswith("conv"):
            idx = int(key[4]) - 1
            c_in = 3 if idx == 0 else models.ALEX_CONV_CH[idx - 1]
            arr = arr.reshape(arr.shape[0], c_in, 3, 3)
        if model == "resnet_mini" and key.endswith(".w") and not key.startswith("fc"):
            name = key[:-2]
            for pname, c_in, c_out, _s, k in models.resnet_conv_plan():
                if pname == name:
                    arr = arr.reshape(c_out, c_in, k, k)
                    break
        params[key] = jnp.asarray(arr)
    return params


def lower_quantized(art: str, config_path: str, log=print):
    """Second-pass lowering: DNA-TEQ-quantized AlexNet from a rust
    QuantConfig (closes the loop rust-calibration → quantized HLO)."""
    log(f"[quantized] lowering with {config_path}")
    with open(config_path) as f:
        cfg = json.load(f)
    by_name = {l["name"]: l for l in cfg["layers"]}
    params = load_params_from_bt(art, "alexnet_mini")

    def fq(name, t, which):
        lq = by_name.get(name)
        if lq is None:
            return t
        side = lq["weights"] if which == "w" else lq["acts"]
        return exp_roundtrip_pallas(t, lq["base"], side["alpha"], side["beta"], int(lq["n_bits"]))

    dump_hlo(
        os.path.join(art, "alexnet_dnateq.hlo.txt"),
        lambda x: (models.alexnet_forward(params, x, fake_quant=fq),),
        jax.ShapeDtypeStruct((1, 3, 32, 32), jnp.float32),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="artifacts dir (default: ../artifacts)")
    ap.add_argument("--force", action="store_true", help="rebuild even if stamp matches")
    ap.add_argument("--steps-scale", type=float, default=1.0, help="scale training budgets")
    ap.add_argument("--quantized", default=None, help="QuantConfig JSON → quantized HLO pass")
    ap.add_argument("--lower-only", action="store_true", help="re-lower HLO from dumped weights")
    args = ap.parse_args()

    here = os.path.dirname(os.path.abspath(__file__))
    art = args.out or os.path.join(here, "..", "..", "artifacts")
    art = os.path.abspath(art)
    os.makedirs(art, exist_ok=True)

    if args.quantized:
        lower_quantized(art, args.quantized)
        return

    if args.lower_only:
        import json as _json

        trained = {}
        for m in ["alexnet_mini", "resnet_mini", "transformer_mini"]:
            man = _json.load(open(os.path.join(art, "models", m, "manifest.json")))
            acc = man.get("baseline_top1", man.get("baseline_token_acc", 0.0))
            trained[m] = (load_params_from_bt(art, m), acc)
        lower_hlo(art, trained)
        return

    stamp_path = os.path.join(art, ".stamp.json")
    stamp = {"version": STAMP_VERSION, "seed": SEED, "steps_scale": args.steps_scale}
    if not args.force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if json.load(f) == stamp:
                print(f"artifacts up to date in {art} (stamp v{STAMP_VERSION}); use --force to rebuild")
                return

    t0 = time.time()
    splits, seq_splits = build_datasets(os.path.join(art, "data"))
    trained = train_models(art, splits, seq_splits, args.steps_scale)
    lower_hlo(art, trained)
    with open(stamp_path, "w") as f:
        json.dump(stamp, f)
    print(f"artifacts built in {time.time()-t0:.1f}s → {art}")


if __name__ == "__main__":
    main()
