"""L2 JAX model zoo — mirrors ``rust/src/nn/{alexnet,resnet,transformer}.rs``
weight-for-weight (same layer names, shapes and forward semantics), so the
trained parameters dump straight into the rust engine.

Conventions shared with rust:
  * images NCHW f32, conv weights exported as ``[c_out, c_in·k·k]``;
  * FC weights ``[out, in]``;
  * LayerNorm eps 1e-5; sinusoidal positions ``pos/10000^(2(i//2)/d)``
    (sin on even dims, cos on odd);
  * transformer: pre-LN, 4 heads, d=128, ff=256, 2+2 layers, vocab 32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def conv2d(x, w, b, stride=1, pad=1):
    """NCHW conv; w is [out, in, k, k]."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def maxpool2x2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def linear(x, w, b):
    """x [., in] @ w[out, in]^T + b."""
    return x @ w.T + b


def layernorm(x, g, b, eps=1e-5):
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def positional(length: int, d: int) -> np.ndarray:
    """Sinusoidal positions — must match rust `add_positional` exactly."""
    pe = np.zeros((length, d), dtype=np.float32)
    for pos in range(length):
        for i in range(d):
            angle = pos / (10000.0 ** ((2 * (i // 2)) / d))
            pe[pos, i] = math.sin(angle) if i % 2 == 0 else math.cos(angle)
    return pe


# ---------------------------------------------------------------------------
# AlexNet-mini (5 conv + 3 fc; pools after conv1, conv2, conv5)
# ---------------------------------------------------------------------------

ALEX_CONV_CH = [32, 64, 96, 96, 64]
ALEX_FC_DIMS = [64 * 4 * 4, 256, 128, 10]


def init_alexnet(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    p = {}
    c_in = 3
    for i, c_out in enumerate(ALEX_CONV_CH):
        fan = c_in * 9
        p[f"conv{i+1}.w"] = rng.normal(0, math.sqrt(2.0 / fan), (c_out, c_in, 3, 3)).astype(
            np.float32
        )
        p[f"conv{i+1}.b"] = np.zeros(c_out, np.float32)
        c_in = c_out
    for i in range(3):
        fan = ALEX_FC_DIMS[i]
        p[f"fc{i+1}.w"] = rng.normal(0, math.sqrt(2.0 / fan), (ALEX_FC_DIMS[i + 1], fan)).astype(
            np.float32
        )
        p[f"fc{i+1}.b"] = np.zeros(ALEX_FC_DIMS[i + 1], np.float32)
    return {k: jnp.asarray(v) for k, v in p.items()}


def alexnet_forward(params, x, fake_quant=None):
    """x: [n, 3, 32, 32] → logits [n, 10].

    ``fake_quant``: optional ``fn(layer_name, tensor, which) -> tensor``
    hook applying quantization to weights (`which='w'`) and layer inputs
    (`which='a'`) — used by the DNA-TEQ AOT variant to splice the L1
    Pallas quantizer into the graph.
    """
    fq = fake_quant or (lambda name, t, which: t)
    for i in range(5):
        name = f"conv{i+1}"
        x = fq(name, x, "a")
        x = conv2d(x, fq(name, params[f"{name}.w"], "w"), params[f"{name}.b"])
        x = jax.nn.relu(x)
        if i in (0, 1, 4):
            x = maxpool2x2(x)
    x = x.reshape(x.shape[0], -1)
    for i in range(3):
        name = f"fc{i+1}"
        x = fq(name, x, "a")
        x = linear(x, fq(name, params[f"{name}.w"], "w"), params[f"{name}.b"])
        if i < 2:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# ResNet-mini (stem + 3 stages × 2 basic blocks + fc head)
# ---------------------------------------------------------------------------

RES_STAGE_CH = [16, 32, 64]


def resnet_conv_plan():
    """(name, c_in, c_out, stride, k) in forward order — mirrors rust."""
    plan = [("conv0", 3, RES_STAGE_CH[0], 1, 3)]
    c_in = RES_STAGE_CH[0]
    for s, c_out in enumerate(RES_STAGE_CH):
        for b in range(2):
            stride = 2 if (s > 0 and b == 0) else 1
            plan.append((f"s{s+1}b{b+1}c1", c_in, c_out, stride, 3))
            plan.append((f"s{s+1}b{b+1}c2", c_out, c_out, 1, 3))
            if c_in != c_out or stride != 1:
                plan.append((f"s{s+1}b{b+1}d", c_in, c_out, stride, 1))
            c_in = c_out
    return plan


def init_resnet(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    p = {}
    for name, c_in, c_out, _stride, k in resnet_conv_plan():
        fan = c_in * k * k
        p[f"{name}.w"] = rng.normal(0, math.sqrt(2.0 / fan), (c_out, c_in, k, k)).astype(
            np.float32
        )
        p[f"{name}.b"] = np.zeros(c_out, np.float32)
    p["fc.w"] = rng.normal(0, 0.2, (10, RES_STAGE_CH[2])).astype(np.float32)
    p["fc.b"] = np.zeros(10, np.float32)
    return {k: jnp.asarray(v) for k, v in p.items()}


def resnet_forward(params, x, fake_quant=None):
    fq = fake_quant or (lambda name, t, which: t)

    def conv(name, x, stride, pad):
        xi = fq(name, x, "a")
        return conv2d(xi, fq(name, params[f"{name}.w"], "w"), params[f"{name}.b"], stride, pad)

    x = jax.nn.relu(conv("conv0", x, 1, 1))
    c_in = RES_STAGE_CH[0]
    for s, c_out in enumerate(RES_STAGE_CH):
        for b in range(2):
            stride = 2 if (s > 0 and b == 0) else 1
            h = jax.nn.relu(conv(f"s{s+1}b{b+1}c1", x, stride, 1))
            h = conv(f"s{s+1}b{b+1}c2", h, 1, 1)
            if c_in != c_out or stride != 1:
                shortcut = conv(f"s{s+1}b{b+1}d", x, stride, 0)
            else:
                shortcut = x
            x = jax.nn.relu(h + shortcut)
            c_in = c_out
    x = x.mean(axis=(2, 3))  # global average pool
    x = fq("fc", x, "a")
    return linear(x, fq("fc", params["fc.w"], "w"), params["fc.b"])


# ---------------------------------------------------------------------------
# Transformer-mini (pre-LN encoder-decoder)
# ---------------------------------------------------------------------------

VOCAB, D_MODEL, N_HEADS, D_FF, N_ENC, N_DEC = 32, 128, 4, 256, 2, 2
HEAD_DIM = D_MODEL // N_HEADS
PAD, BOS, EOS = 0, 1, 2


def init_transformer(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    p = {}

    def lin(name, o, i):
        p[f"{name}.w"] = rng.normal(0, math.sqrt(1.0 / i), (o, i)).astype(np.float32)
        p[f"{name}.b"] = np.zeros(o, np.float32)

    def ln(name):
        p[f"{name}.g"] = np.ones(D_MODEL, np.float32)
        p[f"{name}.b"] = np.zeros(D_MODEL, np.float32)

    p["src_emb"] = rng.normal(0, 0.1, (VOCAB, D_MODEL)).astype(np.float32)
    p["tgt_emb"] = rng.normal(0, 0.1, (VOCAB, D_MODEL)).astype(np.float32)
    for i in range(N_ENC):
        for q in ["q", "k", "v", "o"]:
            lin(f"enc{i}.{q}", D_MODEL, D_MODEL)
        lin(f"enc{i}.ff1", D_FF, D_MODEL)
        lin(f"enc{i}.ff2", D_MODEL, D_FF)
        ln(f"enc{i}.ln1")
        ln(f"enc{i}.ln2")
    for i in range(N_DEC):
        for q in ["s.q", "s.k", "s.v", "s.o", "c.q", "c.k", "c.v", "c.o"]:
            lin(f"dec{i}.{q}", D_MODEL, D_MODEL)
        lin(f"dec{i}.ff1", D_FF, D_MODEL)
        lin(f"dec{i}.ff2", D_MODEL, D_FF)
        ln(f"dec{i}.ln1")
        ln(f"dec{i}.ln2")
        ln(f"dec{i}.ln3")
    ln("enc_ln")
    ln("dec_ln")
    lin("out", VOCAB, D_MODEL)
    return {k: jnp.asarray(v) for k, v in p.items()}


def _attention(params, prefix, x_q, x_kv, mask, fq):
    """Batched multi-head attention. x_q [n, Lq, d], x_kv [n, Lkv, d];
    mask [n, Lq, Lkv] additive (0 or -inf)."""
    n, lq, _ = x_q.shape
    lkv = x_kv.shape[1]

    def proj(name, x):
        xi = fq(name, x, "a")
        return linear(xi, fq(name, params[f"{name}.w"], "w"), params[f"{name}.b"])

    q = proj(f"{prefix}.q", x_q).reshape(n, lq, N_HEADS, HEAD_DIM)
    k = proj(f"{prefix}.k", x_kv).reshape(n, lkv, N_HEADS, HEAD_DIM)
    v = proj(f"{prefix}.v", x_kv).reshape(n, lkv, N_HEADS, HEAD_DIM)
    scores = jnp.einsum("nqhd,nkhd->nhqk", q, k) / math.sqrt(HEAD_DIM)
    scores = scores + mask[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("nhqk,nkhd->nqhd", probs, v).reshape(n, lq, D_MODEL)
    return proj(f"{prefix}.o", ctx)


def _ff(params, prefix, x, fq):
    def proj(name, x, act=False):
        xi = fq(name, x, "a")
        y = linear(xi, fq(name, params[f"{name}.w"], "w"), params[f"{name}.b"])
        return jax.nn.relu(y) if act else y

    return proj(f"{prefix}.ff2", proj(f"{prefix}.ff1", x, act=True))


def transformer_encode(params, src, fake_quant=None):
    """src: [n, L] int32 (PAD-filled) → [n, L, d]."""
    fq = fake_quant or (lambda name, t, which: t)
    n, length = src.shape
    x = params["src_emb"][src] + jnp.asarray(positional(length, D_MODEL))[None]
    pad_mask = jnp.where(src == PAD, -1e9, 0.0)[:, None, :]  # [n, 1, Lkv]
    mask = jnp.broadcast_to(pad_mask, (n, length, length))
    for i in range(N_ENC):
        h = layernorm(x, params[f"enc{i}.ln1.g"], params[f"enc{i}.ln1.b"])
        x = x + _attention(params, f"enc{i}", h, h, mask, fq)
        h = layernorm(x, params[f"enc{i}.ln2.g"], params[f"enc{i}.ln2.b"])
        x = x + _ff(params, f"enc{i}", h, fq)
    return layernorm(x, params["enc_ln.g"], params["enc_ln.b"])


def transformer_decode(params, tgt, enc_out, src, fake_quant=None):
    """tgt: [n, Lt] int32 → logits [n, Lt, vocab]."""
    fq = fake_quant or (lambda name, t, which: t)
    n, lt = tgt.shape
    ls = enc_out.shape[1]
    x = params["tgt_emb"][tgt] + jnp.asarray(positional(lt, D_MODEL))[None]
    causal = jnp.where(jnp.arange(lt)[None, :] > jnp.arange(lt)[:, None], -1e9, 0.0)
    tgt_pad = jnp.where(tgt == PAD, -1e9, 0.0)[:, None, :]
    self_mask = jnp.broadcast_to(causal[None], (n, lt, lt)) + tgt_pad
    cross_mask = jnp.broadcast_to(jnp.where(src == PAD, -1e9, 0.0)[:, None, :], (n, lt, ls))
    for i in range(N_DEC):
        h = layernorm(x, params[f"dec{i}.ln1.g"], params[f"dec{i}.ln1.b"])
        x = x + _attention(params, f"dec{i}.s", h, h, self_mask, fq)
        h = layernorm(x, params[f"dec{i}.ln2.g"], params[f"dec{i}.ln2.b"])
        x = x + _attention(params, f"dec{i}.c", h, enc_out, cross_mask, fq)
        h = layernorm(x, params[f"dec{i}.ln3.g"], params[f"dec{i}.ln3.b"])
        x = x + _ff(params, f"dec{i}", h, fq)
    x = layernorm(x, params["dec_ln.g"], params["dec_ln.b"])
    xo = fq("out", x, "a")
    return linear(xo, fq("out", params["out.w"], "w"), params["out.b"])


# ---------------------------------------------------------------------------
# Export helpers
# ---------------------------------------------------------------------------


def export_weights(params: dict, model: str) -> dict:
    """Reshape to the rust layouts: conv [out, in·k·k]; pass through FC,
    embeddings and norms."""
    out = {}
    for k, v in params.items():
        arr = np.asarray(v)
        if arr.ndim == 4:  # conv OIHW → [O, I*K*K]
            arr = arr.reshape(arr.shape[0], -1)
        out[k] = arr.astype(np.float32)
    return out
