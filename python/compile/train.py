"""Build-time training of the mini model zoo (runs once in `make
artifacts`). Hand-rolled Adam (no optimizer deps), jitted steps,
single-host CPU."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, models


# ---------------------------------------------------------------------------
# Minimal Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Classifier training (AlexNet-mini / ResNet-mini)
# ---------------------------------------------------------------------------


def _ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def train_classifier(forward, params, images, labels, *, steps, batch, lr, seed, log=print):
    """SGD over the synthetic image task; returns trained params."""
    state = adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, xb, yb):
        def loss_fn(p):
            return _ce_loss(forward(p, xb), yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    n = images.shape[0]
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, state, loss = step(params, state, jnp.asarray(images[idx]), jnp.asarray(labels[idx]))
        if s % 50 == 0 or s == steps - 1:
            log(f"  step {s:4d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    return params


def eval_classifier(forward, params, images, labels, batch=64):
    hits = 0
    fwd = jax.jit(forward)
    for i in range(0, images.shape[0], batch):
        logits = fwd(params, jnp.asarray(images[i : i + batch]))
        hits += int((np.asarray(logits).argmax(-1) == labels[i : i + batch]).sum())
    return hits / images.shape[0]


# ---------------------------------------------------------------------------
# Transformer training
# ---------------------------------------------------------------------------


def train_transformer(params, src, tgt, *, steps, batch, lr, seed, log=print):
    state = adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, sb, tb):
        def loss_fn(p):
            enc = models.transformer_encode(p, sb)
            logits = models.transformer_decode(p, tb[:, :-1], enc, sb)
            gold = tb[:, 1:]
            mask = (gold != datagen.PAD).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits)
            nll = -jnp.take_along_axis(logp, gold[..., None], axis=-1)[..., 0]
            return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    n = src.shape[0]
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, state, loss = step(params, state, jnp.asarray(src[idx]), jnp.asarray(tgt[idx]))
        if s % 100 == 0 or s == steps - 1:
            log(f"  step {s:4d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    return params


def eval_transformer(params, src, tgt, batch=64):
    """Teacher-forced next-token accuracy over non-PAD positions."""
    hits, total = 0, 0

    @jax.jit
    def fwd(params, sb, tb):
        enc = models.transformer_encode(params, sb)
        return models.transformer_decode(params, tb[:, :-1], enc, sb)

    for i in range(0, src.shape[0], batch):
        sb = jnp.asarray(src[i : i + batch])
        tb = jnp.asarray(tgt[i : i + batch])
        logits = np.asarray(fwd(params, sb, tb))
        gold = np.asarray(tb)[:, 1:]
        mask = gold != datagen.PAD
        hits += int(((logits.argmax(-1) == gold) & mask).sum())
        total += int(mask.sum())
    return hits / max(total, 1)
